"""Noise-adaptive frontier: ONE run traverses the paper's trade-off.

The paper's Tables 2/4 sweep static (H, compression, batch) points and
report the communication/performance frontier.  The composite
``noise_adaptive`` controller (ISSUE 7) walks that frontier online in a
SINGLE run from one telemetry stream:

  * starts mini-batch-like: H=1, uncompressed, batch scale 1, lr 1.0
  * gradient-diversity collapse ramps H up (Table 2's H axis)
  * the measured compression error turns the 1-bit EF-sign wire on
    per bucket (Table 4's compression axis)
  * the measured gradient-noise scale (signal/noise split of the
    per-worker update norms) grows the per-worker batch while the
    total batch is noise-dominated, then hands off to LR decay at the
    batch cap (the Lau et al. 2024 schedule, bounded per Golmant et
    al. 2018)

Workload: the synthetic cluster-classification MLP (CIFAR/ResNet-20
stand-in, benchmarks/common.py).  Two runs, same data and step budget:

  * static_h1       — H=1, dense sync (the max-communication baseline)
  * noise_adaptive  — the composite controller, all axes live

Prints the traversed frontier per round (H, modes, batch/LR scale,
B_noise) and checks the ISSUE-7 acceptance: ends H>=8 + compressed,
>=5x fewer wire bytes than static H=1, test accuracy no worse.

    PYTHONPATH=src python examples/noise_adaptive_frontier.py
"""
import json
import pathlib
import sys

root = pathlib.Path(__file__).parent.parent
sys.path[:0] = [str(root / "src"), str(root)]

import jax

from benchmarks.common import DIM, dataset, mlp_loss, test_acc
from repro.configs.base import (ControllerConfig, InputShape, LocalSGDConfig,
                                ModelConfig, OptimConfig, RunConfig)
from repro.core import flatbuf
from repro.core.local_sgd import make_local_sgd, mean_params
from repro.data.partition import ShardedBatches
from repro.launch.steps import TrainBundle
from repro.launch.train import fit
from repro.models.base import ParamSpec

K, B_LOC, STEPS, WIDTH = 8, 64, 160, 128

train, test = dataset()


def mlp_specs(width=WIDTH):
    import benchmarks.common as bc
    return {"w1": ParamSpec((DIM, width), (None, None)),
            "b1": ParamSpec((width,), (None,), init="zeros"),
            "w2": ParamSpec((width, width), (None, None)),
            "b2": ParamSpec((width,), (None,), init="zeros"),
            "w3": ParamSpec((width, bc.CLASSES), (None, None)),
            "b3": ParamSpec((bc.CLASSES,), (None,), init="zeros")}


def make_bundle(run: RunConfig) -> TrainBundle:
    """Resident-path bundle (per-bucket compressor modes + fused-kernel
    telemetry), meshless."""
    cc = run.controller
    init, local_step, sync = make_local_sgd(
        run, mlp_loss, num_workers=K, use_kernel=True,
        telemetry=cc.wants_telemetry,
        speculate_compression=cc.wants_speculation)
    specs = mlp_specs()
    n_comp = flatbuf.build_layout(
        {k: jax.ShapeDtypeStruct(s.shape, "float32")
         for k, s in specs.items()}).num_buckets
    return TrainBundle(
        cfg=run.model, run=run, layout=None, num_workers=K,
        specs=specs, init=init,
        local_step=jax.jit(local_step),
        sync=jax.jit(sync, static_argnames=("group", "compression",
                                            "plan", "scope")),
        telemetry=cc.wants_telemetry, n_comp=n_comp)


def run_one(name, ls, controller, telemetry_path=None):
    run = RunConfig(
        model=ModelConfig(name="mlp", family="dense", citation=""),
        shape=InputShape("frontier", DIM, K * B_LOC, "train"),
        local_sgd=ls, controller=controller,
        optim=OptimConfig(base_lr=0.15, base_batch=K * B_LOC,
                          lr_warmup_steps=STEPS // 20,
                          lr_decay_steps=(STEPS // 2, 3 * STEPS // 4),
                          weight_decay=1e-4),
        steps=STEPS)
    state, hist, summary = fit(run, ShardedBatches(train, K, B_LOC),
                               bundle=make_bundle(run), num_steps=STEPS,
                               telemetry_path=telemetry_path)
    return {"name": name, "acc": test_acc(mean_params(state), test),
            "loss": hist[-1]["loss"],
            "rounds": summary["ledger"]["sync_rounds"],
            "wire_mb": summary["ledger"]["wire_bytes"] / 1e6,
            "scaling": summary["ledger"]["scaling"],
            "controller": summary["controller"]}


def main():
    tdir = pathlib.Path("telemetry")
    tdir.mkdir(exist_ok=True)
    base = run_one("static_h1", LocalSGDConfig(local_steps=1),
                   ControllerConfig(kind="static", telemetry=True),
                   tdir / "frontier_h1.jsonl")
    adapt = run_one(
        "noise_adaptive",
        LocalSGDConfig(local_steps=1, sync_compression="ef_sign",
                       wire_pack=True),
        ControllerConfig(kind="noise_adaptive", h0=1, h_max=16,
                         low=0.55, high=1.8, err_budget=0.9,
                         patience=1, max_batch_scale=8, noise_grow=0.25,
                         lr_cap_decay=0.5, lr_scale_min=0.1),
        tdir / "frontier_noise_adaptive.jsonl")

    print(f"\n{'config':<16} {'test acc':>9} {'final loss':>11} "
          f"{'sync rounds':>12} {'wire MB':>10}")
    for r in (base, adapt):
        print(f"{r['name']:<16} {r['acc']:>9.3f} {r['loss']:>11.4f} "
              f"{r['rounds']:>12d} {r['wire_mb']:>10.3f}")

    recs = [json.loads(l)
            for l in open(tdir / "frontier_noise_adaptive.jsonl")]
    print("\ntraversed frontier (telemetry/frontier_noise_adaptive.jsonl):")
    print(f"  {'round':>5} {'h':>3} {'batch':>6} {'lr_scale':>8} "
          f"{'modes':>18} {'B_noise/B':>10}")
    for r in recs:
        bn = r.get("noise_ratio", 0.0) * (B_LOC * r["next_batch_scale"])
        ratio = bn / (K * B_LOC * r["next_batch_scale"])
        # signal_sq ~ 0 rounds (pure noise) give unbounded ratios
        cell = f"{ratio:.2f}" if ratio < 1e3 else ">1e3"
        print(f"  {r['round']:>5} {r['h']:>3} {r['next_batch_scale']:>6} "
              f"{r['next_lr_scale']:>8.3f} {r['next_compression']:>18} "
              f"{cell:>10}")

    first, last = recs[0], recs[-1]
    reduction = base["wire_mb"] / max(adapt["wire_mb"], 1e-9)
    checks = [
        # round 1 syncs BEFORE any controller decision lands: H=1,
        # modes all-none (its wire bytes are the dense f32 payload,
        # far above any later 1-bit round), batch/lr scale 1
        ("starts H=1 uncompressed batch x1",
         first["h"] == 1 and first["next_batch_scale"] == 1
         and first["wire_bytes"] > 5 * last["wire_bytes"]),
        ("ends H>=8", last["h"] >= 8),
        ("ends compressed", "sign" in last["next_compression"]),
        ("ends large-batch (scale>1)", last["next_batch_scale"] > 1),
        (">=5x wire reduction vs static H=1", reduction >= 5.0),
        ("test acc no worse than static H=1 (-1% tol)",
         adapt["acc"] >= base["acc"] - 0.01),
    ]
    print(f"\nnoise_adaptive vs static H=1: {reduction:.1f}x fewer wire "
          f"bytes at test acc {adapt['acc']:.3f} vs {base['acc']:.3f}")
    ok = True
    for name, passed in checks:
        print(f"  [{'ok' if passed else 'FAIL'}] {name}")
        ok &= bool(passed)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
