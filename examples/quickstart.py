"""Quickstart: post-local SGD on a tiny LM in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, pathlib
sys.path.insert(0, str(pathlib.Path(__file__).parent.parent / "src"))

import jax

from repro import configs
from repro.configs.base import InputShape, LocalSGDConfig, OptimConfig, RunConfig
from repro.data.partition import ShardedBatches
from repro.data.synthetic import lm_examples, markov_lm
from repro.launch.steps import build_train
from repro.launch.train import eval_lm, fit

K, B_LOC, SEQ, STEPS = 4, 4, 64, 40

cfg = configs.get_smoke("paper-lm")                 # tiny decoder LM
run = RunConfig(
    model=cfg,
    shape=InputShape("quickstart", SEQ, K * B_LOC, "train"),
    # post-local SGD (paper Alg. 2): mini-batch SGD for the first half,
    # then H=4 local steps between synchronizations.
    local_sgd=LocalSGDConfig(local_steps=4, post_local_switch=STEPS // 2),
    optim=OptimConfig(base_lr=0.3, base_batch=K * B_LOC,
                      lr_warmup_steps=4, lr_decay_steps=(STEPS // 2,)),
)

data = lm_examples(markov_lm(vocab=cfg.vocab_size, num_seqs=512, seq_len=SEQ))
held = lm_examples(markov_lm(vocab=cfg.vocab_size, num_seqs=64, seq_len=SEQ,
                             sample_seed=99))
batches = ShardedBatches(data, K, B_LOC)            # disjoint shards per worker

bundle = build_train(run, num_workers=K)
state, history, summary = fit(run, batches, bundle=bundle, num_steps=STEPS,
                              eval_every=10, eval_fn=eval_lm(bundle, held))

print(f"\nfinal train loss: {history[-1]['loss']:.3f}")
print(f"communication rounds: {summary['comm_rounds']} "
      f"(mini-batch SGD would use {STEPS})")
