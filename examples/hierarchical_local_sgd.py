"""Hierarchical local SGD (paper Alg. 5 / Appendix D) demo — on the
SyncPlan Topology API (ISSUE 5).

Two blocks of workers; inner (block) syncs every H steps, outer (global)
syncs every H*H^b.  The sync topology is DECLARED, not implied by a
``group=`` kwarg: ``make_sync_plan(bundle, topology=hierarchical(2))``
compiles the per-sub-bucket sync into block-mean stages (fast intra-pod
links) and global stages (slow inter-pod links), and the comms ledger
prices each stage — so the Alg. 5 trade-off (cheap inner rounds vs
expensive outer rounds) prints straight from ``summary['ledger']``.

    PYTHONPATH=src python examples/hierarchical_local_sgd.py
"""
import sys, pathlib
root = pathlib.Path(__file__).parent.parent
sys.path[:0] = [str(root / "src"), str(root)]

import jax
import numpy as np

from repro import configs
from repro.configs.base import InputShape, LocalSGDConfig, OptimConfig, RunConfig
from repro.core.syncplan import hierarchical, make_sync_plan
from repro.data.partition import ShardedBatches
from repro.data.synthetic import lm_examples, markov_lm
from repro.launch.steps import build_train
from repro.launch.train import fit

K, B_LOC, SEQ, STEPS = 4, 4, 64, 36
H, HB = 2, 3                       # inner steps, block steps
BLOCK = K // 2                     # workers per block (two blocks)

cfg = configs.get_smoke("paper-lm")
run = RunConfig(model=cfg,
                shape=InputShape("hier", SEQ, K * B_LOC, "train"),
                local_sgd=LocalSGDConfig(local_steps=H, block_steps=HB),
                optim=OptimConfig(base_lr=0.3, base_batch=K * B_LOC,
                                  lr_decay_steps=(STEPS // 2,)))

data = lm_examples(markov_lm(vocab=cfg.vocab_size, num_seqs=512, seq_len=SEQ))
bundle = build_train(run, num_workers=K)
# Declare the Alg. 5 topology explicitly: block-mean stages over blocks
# of BLOCK consecutive workers, then the global stages.  (build_train's
# 'auto' topology compiles the same plan from block_steps > 1; spelling
# it out here shows the API the controller's PlanDelta also rewrites.)
bundle.sync_plan = make_sync_plan(bundle, topology=hierarchical(BLOCK))
print(bundle.sync_plan.describe())
print()

state, hist, summary = fit(run, ShardedBatches(data, K, B_LOC), bundle=bundle,
                           num_steps=STEPS)

print(f"H={H}, H^b={HB}, steps={STEPS}, topology={summary['topology']}")
print(f"block syncs (fast intra-pod links):  {summary['comm_rounds']['block']}")
print(f"global syncs (slow inter-pod links): {summary['comm_rounds']['global']}")
print(f"mini-batch SGD would do {STEPS} global syncs")

print("\nper-stage ledger (Alg. 5 trade-off, bytes per device per round):")
for key, row in sorted(summary["ledger"]["topologies"].items()):
    print(f"  {key:22s} rounds={row['rounds']:3d} "
          f"bytes/round={row['bytes_per_round']:10.0f} "
          f"collectives={row['collectives']}")

w = jax.tree.leaves(state.params)[0]
spread = float(np.abs(np.float32(w[0]) - np.float32(w[-1])).max())
print(f"\nmax param spread across workers after final sync: {spread:.2e}")
