"""Hierarchical local SGD (paper Alg. 5 / Appendix D) demo.

Two blocks of workers; inner (block) syncs every H steps, outer (global)
syncs every H*H^b. Shows the two-level communication accounting and that
all workers converge to one model after the final global sync.

    PYTHONPATH=src python examples/hierarchical_local_sgd.py
"""
import sys, pathlib
root = pathlib.Path(__file__).parent.parent
sys.path[:0] = [str(root / "src"), str(root)]

import jax
import numpy as np

from repro import configs
from repro.configs.base import InputShape, LocalSGDConfig, OptimConfig, RunConfig
from repro.data.partition import ShardedBatches
from repro.data.synthetic import lm_examples, markov_lm
from repro.launch.steps import build_train
from repro.launch.train import fit

K, B_LOC, SEQ, STEPS = 4, 4, 64, 36
H, HB = 2, 3                       # inner steps, block steps

cfg = configs.get_smoke("paper-lm")
run = RunConfig(model=cfg,
                shape=InputShape("hier", SEQ, K * B_LOC, "train"),
                local_sgd=LocalSGDConfig(local_steps=H, block_steps=HB),
                optim=OptimConfig(base_lr=0.3, base_batch=K * B_LOC,
                                  lr_decay_steps=(STEPS // 2,)))

data = lm_examples(markov_lm(vocab=cfg.vocab_size, num_seqs=512, seq_len=SEQ))
bundle = build_train(run, num_workers=K)
state, hist, summary = fit(run, ShardedBatches(data, K, B_LOC), bundle=bundle,
                           num_steps=STEPS)

print(f"H={H}, H^b={HB}, steps={STEPS}")
print(f"block syncs (fast intra-pod links):  {summary['comm_rounds']['block']}")
print(f"global syncs (slow inter-pod links): {summary['comm_rounds']['global']}")
print(f"mini-batch SGD would do {STEPS} global syncs")

w = jax.tree.leaves(state.params)[0]
spread = float(np.abs(np.float32(w[0]) - np.float32(w[-1])).max())
print(f"max param spread across workers after final sync: {spread:.2e}")
