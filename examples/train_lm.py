"""End-to-end training driver (deliverable b): train the paper-lm model
with post-local SGD on the synthetic LM corpus, with checkpointing and
held-out evaluation.

Default is the fast tiny preset; ``--preset 100m --steps 300`` runs the
~100M configuration (sized for real hardware; slow on this CPU box).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import sys, pathlib
root = pathlib.Path(__file__).parent.parent
sys.path[:0] = [str(root / "src"), str(root)]

import argparse

from repro import configs
from repro.checkpoint.checkpoint import save
from repro.configs.base import InputShape, LocalSGDConfig, OptimConfig, RunConfig
from repro.configs import paper_lm
from repro.data.partition import ShardedBatches
from repro.data.synthetic import lm_examples, markov_lm
from repro.launch.steps import build_train
from repro.launch.train import eval_lm, fit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "100m"], default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--local-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = paper_lm.tiny() if args.preset == "tiny" else configs.get("paper-lm")
    shape = InputShape("train", args.seq, args.workers * args.local_batch,
                       "train")
    run = RunConfig(
        model=cfg, shape=shape,
        local_sgd=LocalSGDConfig(local_steps=args.local_steps,
                                 post_local_switch=args.steps // 2),
        optim=OptimConfig(base_lr=0.3, base_batch=shape.global_batch,
                          lr_warmup_steps=max(args.steps // 20, 1),
                          lr_decay_steps=(args.steps // 2,
                                          3 * args.steps // 4)))

    data = lm_examples(markov_lm(vocab=cfg.vocab_size, num_seqs=1024,
                                 seq_len=args.seq))
    held = lm_examples(markov_lm(vocab=cfg.vocab_size, num_seqs=64,
                                 seq_len=args.seq, sample_seed=7))
    bundle = build_train(run, num_workers=args.workers)
    state, hist, summary = fit(run, ShardedBatches(data, args.workers,
                                                   args.local_batch),
                               bundle=bundle, num_steps=args.steps,
                               eval_every=max(args.steps // 4, 1),
                               eval_fn=eval_lm(bundle, held))
    save(args.ckpt, state, step=int(state.step),
         extra={"arch": cfg.name, "H": args.local_steps})
    print(f"\ntrained {cfg.name}: final loss {hist[-1]['loss']:.3f}, "
          f"comm rounds {summary['comm_rounds']}, checkpoint -> {args.ckpt}.npz")


if __name__ == "__main__":
    main()
