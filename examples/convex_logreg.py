"""Paper Appendix B.2: local SGD on a convex problem (logistic regression).

Reproduces Figure 6's protocol on the synthetic w8a stand-in: time to a
target suboptimality under a simulated communication cost of 25 gradient
steps, over a grid of H.

    PYTHONPATH=src:. python examples/convex_logreg.py
"""
import sys, pathlib
root = pathlib.Path(__file__).parent.parent
sys.path[:0] = [str(root / "src"), str(root)]

from benchmarks.bench_convex import _best_over_lrs

print(f"{'config':14s} {'sim time':>9s} {'steps':>6s} {'comm':>5s} {'hit':>5s}")
base = None
for H in (1, 2, 4, 8, 16):
    sim, steps, comm, hit = _best_over_lrs(K=8, H=H, B_loc=16)
    base = base or sim
    print(f"K=8 H={H:<3d}      {sim:9.0f} {steps:6d} {comm:5d} {str(hit):>5s}"
          f"   ({base/sim:.2f}x vs H=1)")
print("\nLocal SGD reaches the target with far fewer synchronizations —")
print("the paper's Figure 6 trade-off (comm 25x more expensive than a step).")
