"""Continuous batching + live weight hot-swap on the paged KV cache.

The static wave in examples/serve_lm.py holds every decode slot until
the LAST sequence of the batch finishes.  Here the same model serves a
mixed-length workload through :class:`repro.serving.DecodeEngine`:
short requests retire early, their slots and KV pages go back to the
pool, and queued work is admitted between decode steps.  Mid-run a
"trainer" publishes a new weight snapshot (worker-stacked bucket
buffers, the flat-bus convention) and the engine installs it without
stopping — resident sequences continue exactly as if they had been
restarted on the new version.

    PYTHONPATH=src python examples/serve_continuous.py --arch gemma3-1b
"""
import sys, pathlib
root = pathlib.Path(__file__).parent.parent
sys.path[:0] = [str(root / "src"), str(root)]

import argparse
import tempfile
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import InputShape
from repro.launch.steps import build_engine
from repro.models import base as mbase
from repro.models import lm
from repro.serving import WeightPublisher, WeightSubscriber


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    shape = InputShape("serve", args.max_len, args.batch, "decode")
    eng = build_engine(cfg, shape, page_size=8, prefill_len=8)
    print(f"engine: {eng.describe()}")

    # mixed workload: one long generation per wave of shorts
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(3, 7)))
        eng.submit(prompt, max_new=24 if i % args.batch == 0 else 3)

    # a second "trained" snapshot, published the way the trainer does it:
    # resident bucket buffers at a sync boundary, versioned manifest
    with tempfile.TemporaryDirectory() as d:
        pub = WeightPublisher(d)
        sub = WeightSubscriber(d, lm.param_specs(cfg))
        new_params = mbase.materialize(lm.param_specs(cfg),
                                       jax.random.PRNGKey(1))
        pub.publish(new_params, step=100)

        t0 = time.perf_counter()
        swap_at = args.requests // 2
        while not eng.idle:
            eng.step()
            if len(eng.completed) >= swap_at and eng.weight_version < 0:
                got = eng.poll_weights(sub)
                print(f"hot-swap -> version {got} with "
                      f"{eng.num_active} residents mid-generation")
        dt = time.perf_counter() - t0

    done = eng.completed
    print(f"served {len(done)} requests, {eng.tokens_out} tokens "
          f"in {eng.steps} steps ({eng.tokens_out / dt:.0f} tok/s)")
    for r in done[:4]:
        print(f"  uid={r.uid} finish={r.finish_reason} "
              f"versions={r.weight_versions} tokens={r.tokens[:8]}"
              f"{'...' if len(r.tokens) > 8 else ''}")


if __name__ == "__main__":
    main()
