"""Serve a small model with batched requests: prefill + stepwise decode.

Exercises the same prefill/decode_step paths the dry-run lowers for the
production mesh, on CPU with a smoke config.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-1b --batch 4
"""
import sys, pathlib
root = pathlib.Path(__file__).parent.parent
sys.path[:0] = [str(root / "src"), str(root)]

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import InputShape
from repro.launch.steps import build_serve
from repro.models import base as mbase
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    max_len = args.prompt_len + args.gen
    shape = InputShape("serve", max_len, args.batch, "decode")
    bundle = build_serve(cfg, shape, jit=False)
    params = mbase.materialize(bundle.specs, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (args.batch, args.prompt_len)), jnp.int32)

    t0 = time.perf_counter()
    # prefill straight into the max_len cache template: dtype-preserving
    # and jitted with the forward (no per-run host-side re-pad)
    logits, cache = lm.prefill(cfg, params, prompts, scan=True,
                               max_len=max_len)
    jax.block_until_ready(cache)
    t_prefill = time.perf_counter() - t0

    tok = logits.argmax(-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    step = jax.jit(lambda p, t, c, n: lm.decode_step(cfg, p, t, c, n))
    for i in range(args.gen - 1):
        logits, cache = step(params, tok, cache,
                             jnp.int32(args.prompt_len + i + 1))
        tok = logits.argmax(-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill {args.prompt_len} toks: {t_prefill*1e3:.1f} ms")
    print(f"decode {args.gen-1} steps: {t_decode*1e3:.1f} ms "
          f"({t_decode/(args.gen-1)*1e3:.1f} ms/tok, batched x{args.batch})")
    print("generated token ids (first request):", gen[0].tolist())


if __name__ == "__main__":
    main()
