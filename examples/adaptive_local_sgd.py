"""Adaptive local SGD: the paper's trade-off frontier as ONE run.

The paper's Table 2 / Table 4 study sweeps static configurations (H,
compression) and reports the communication/performance frontier.  With
the telemetry + controller subsystem (ISSUE 3) a single adaptive run
walks that frontier online: the ``diversity_h`` policy grows H as the
measured inter-worker gradient diversity collapses, and the
``auto_compress`` policy turns the sign / EF-sign compressor on per
bucket once the measured compression error fits the budget.

Workload: the synthetic cluster-classification MLP the benchmark suite
uses as its CIFAR/ResNet-20 stand-in (benchmarks/common.py).  Four
configurations, same data and step budget:

  * constant H=1   (mini-batch SGD baseline: max communication)
  * constant H=8   (static local SGD: the paper's pre-scheduled point)
  * diversity_h    (adaptive H from measured diversity)
  * auto_compress  (H=4 + runtime compressor escalation, 1-bit wire)

Prints held-out accuracy vs. ledger wire bytes, plus the adaptive H /
compressor trajectories from the telemetry JSONL logs.

    PYTHONPATH=src python examples/adaptive_local_sgd.py
"""
import json
import pathlib
import sys

root = pathlib.Path(__file__).parent.parent
sys.path[:0] = [str(root / "src"), str(root)]

import jax

from benchmarks.common import DIM, dataset, mlp_loss, test_acc
from repro.configs.base import (ControllerConfig, InputShape, LocalSGDConfig,
                                ModelConfig, OptimConfig, RunConfig)
from repro.core.local_sgd import make_local_sgd
from repro.data.partition import ShardedBatches
from repro.launch.steps import TrainBundle
from repro.launch.train import fit
from repro.models.base import ParamSpec

K, B_LOC, STEPS, WIDTH = 8, 64, 160, 128

train, test = dataset()


def mlp_specs(width=WIDTH):
    """ParamSpec tree matching benchmarks.common.mlp_init."""
    import benchmarks.common as bc
    return {"w1": ParamSpec((DIM, width), (None, None)),
            "b1": ParamSpec((width,), (None,), init="zeros"),
            "w2": ParamSpec((width, width), (None, None)),
            "b2": ParamSpec((width,), (None,), init="zeros"),
            "w3": ParamSpec((width, bc.CLASSES), (None, None)),
            "b3": ParamSpec((bc.CLASSES,), (None,), init="zeros")}


def make_bundle(run: RunConfig) -> TrainBundle:
    cc = run.controller
    init, local_step, sync = make_local_sgd(
        run, mlp_loss, num_workers=K, telemetry=cc.wants_telemetry,
        speculate_compression=cc.wants_speculation)
    return TrainBundle(
        cfg=run.model, run=run, layout=None, num_workers=K,
        specs=mlp_specs(), init=init,
        local_step=jax.jit(local_step),
        sync=jax.jit(sync, static_argnames=("group", "compression")),
        telemetry=cc.wants_telemetry)


def run_one(name, ls, controller, telemetry_path=None):
    run = RunConfig(
        model=ModelConfig(name="mlp", family="dense", citation=""),
        shape=InputShape("adapt", DIM, K * B_LOC, "train"),
        local_sgd=ls, controller=controller,
        optim=OptimConfig(base_lr=0.15, base_batch=K * B_LOC,
                          lr_warmup_steps=STEPS // 20,
                          lr_decay_steps=(STEPS // 2, 3 * STEPS // 4),
                          weight_decay=1e-4),
        steps=STEPS)
    state, hist, summary = fit(run, ShardedBatches(train, K, B_LOC),
                               bundle=make_bundle(run), num_steps=STEPS,
                               telemetry_path=telemetry_path)
    return {"name": name, "acc": test_acc(state, test),
            "loss": hist[-1]["loss"],
            "rounds": summary["ledger"]["sync_rounds"],
            "wire_mb": summary["ledger"]["wire_bytes"] / 1e6,
            "controller": summary["controller"]}


tdir = pathlib.Path("telemetry")
tdir.mkdir(exist_ok=True)
rows = [
    run_one("minibatch_h1", LocalSGDConfig(local_steps=1),
            ControllerConfig(kind="static", telemetry=True),
            tdir / "h1.jsonl"),
    run_one("static_h8", LocalSGDConfig(local_steps=8),
            ControllerConfig(kind="static", telemetry=True),
            tdir / "h8.jsonl"),
    run_one("diversity_h", LocalSGDConfig(local_steps=1),
            ControllerConfig(kind="diversity_h", h0=1, h_max=16,
                             low=0.45, high=0.8),
            tdir / "diversity_h.jsonl"),
    run_one("auto_compress",
            LocalSGDConfig(local_steps=4, sync_compression="ef_sign",
                           wire_pack=True),
            ControllerConfig(kind="auto_compress", err_budget=0.9,
                             patience=1),
            tdir / "auto_compress.jsonl"),
]

print(f"\n{'config':<16} {'test acc':>9} {'final loss':>11} "
      f"{'sync rounds':>12} {'wire MB':>10}")
for r in rows:
    print(f"{r['name']:<16} {r['acc']:>9.3f} {r['loss']:>11.4f} "
          f"{r['rounds']:>12d} {r['wire_mb']:>10.3f}")

print("\nadaptive trajectories (telemetry/*.jsonl):")
for name in ("diversity_h", "auto_compress"):
    recs = [json.loads(l) for l in open(tdir / f"{name}.jsonl")]
    print(f"  {name}: H per round = {[r['h'] for r in recs]}")
    if name == "auto_compress":
        print(f"  {name}: next mode per round = "
              f"{[r['next_compression'] for r in recs]}")
    else:
        print(f"  {name}: diversity per round = "
              f"{[round(r.get('diversity', 0.0), 3) for r in recs]}")

base = next(r for r in rows if r["name"] == "minibatch_h1")
adapt = next(r for r in rows if r["name"] == "diversity_h")
print(f"\ndiversity_h vs H=1: "
      f"{base['wire_mb'] / max(adapt['wire_mb'], 1e-9):.1f}x fewer wire "
      f"bytes at test acc {adapt['acc']:.3f} vs {base['acc']:.3f}")
