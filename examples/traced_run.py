"""Traced run: the bytes<->seconds join on one local-SGD training run.

The trace spine (ISSUE 8) gives every quantity the comms ledger prices
in BYTES a measured wall-clock figure in SECONDS.  This example runs
one local-SGD fit on the synthetic cluster-classification MLP with a
``Tracer`` + ``MetricsRegistry`` threaded through ``fit``, then:

  * writes the full artifact set a ``--trace-dir`` run produces
    (``trace.json`` for ui.perfetto.dev, ``metrics.prom`` Prometheus
    exposition, ``telemetry.jsonl`` extended with ``round_s``/
    ``sync_s``/``stage_s``, ``manifest.json``) and re-validates it with
    the CI schema gate (``repro.telemetry.export.check_trace_dir``);
  * prints the span census and the per-stage JOIN: for each collective
    stage id, the ledger's priced wire bytes next to the trace's
    attributed seconds — same id, two streams.

Durations are measured unfenced by default (dispatch time; see the
README's measurement-semantics note) — pass ``--fence`` for true
wall-clock at the cost of dispatch pipelining.

    PYTHONPATH=src python examples/traced_run.py [--fence]
"""
import argparse
import json
import pathlib
import sys
from collections import Counter

root = pathlib.Path(__file__).parent.parent
sys.path[:0] = [str(root / "src"), str(root)]

import jax

from benchmarks.common import DIM, dataset, mlp_loss, test_acc
from repro.configs.base import (ControllerConfig, InputShape, LocalSGDConfig,
                                ModelConfig, OptimConfig, RunConfig)
from repro.core import flatbuf
from repro.core.local_sgd import make_local_sgd, mean_params
from repro.data.partition import ShardedBatches
from repro.launch.steps import TrainBundle
from repro.launch.train import fit
from repro.models.base import ParamSpec
from repro.telemetry import export as texport
from repro.telemetry.metrics import MetricsRegistry
from repro.telemetry.trace import Tracer

K, B_LOC, STEPS, WIDTH = 8, 32, 64, 128

train, test = dataset()


def make_bundle(run: RunConfig) -> TrainBundle:
    import benchmarks.common as bc
    specs = {"w1": ParamSpec((DIM, WIDTH), (None, None)),
             "b1": ParamSpec((WIDTH,), (None,), init="zeros"),
             "w2": ParamSpec((WIDTH, WIDTH), (None, None)),
             "b2": ParamSpec((WIDTH,), (None,), init="zeros"),
             "w3": ParamSpec((WIDTH, bc.CLASSES), (None, None)),
             "b3": ParamSpec((bc.CLASSES,), (None,), init="zeros")}
    init, local_step, sync = make_local_sgd(run, mlp_loss, num_workers=K,
                                            use_kernel=True, telemetry=True)
    n_comp = flatbuf.build_layout(
        {k: jax.ShapeDtypeStruct(s.shape, "float32")
         for k, s in specs.items()}).num_buckets
    return TrainBundle(
        cfg=run.model, run=run, layout=None, num_workers=K,
        specs=specs, init=init, local_step=jax.jit(local_step),
        sync=jax.jit(sync, static_argnames=("group", "compression",
                                            "plan", "scope")),
        telemetry=True, n_comp=n_comp)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fence", action="store_true",
                    help="block_until_ready at span boundaries (true "
                         "wall-clock, breaks dispatch pipelining)")
    ap.add_argument("--out", default="traced_run_example")
    args = ap.parse_args()

    run = RunConfig(
        model=ModelConfig(name="mlp", family="dense", citation=""),
        shape=InputShape("traced", DIM, K * B_LOC, "train"),
        local_sgd=LocalSGDConfig(local_steps=4, local_momentum=0.9,
                                 sync_compression="sign", wire_pack=True),
        controller=ControllerConfig(kind="static", telemetry=True),
        optim=OptimConfig(base_lr=0.15, base_batch=K * B_LOC,
                          lr_warmup_steps=STEPS // 20,
                          lr_decay_steps=(STEPS // 2, 3 * STEPS // 4),
                          weight_decay=1e-4),
        steps=STEPS)

    out = pathlib.Path(args.out)
    out.mkdir(exist_ok=True)
    tr = Tracer(fence=args.fence, annotate=True, metrics=MetricsRegistry())
    state, hist, summary = fit(
        run, ShardedBatches(train, K, B_LOC), bundle=make_bundle(run),
        num_steps=STEPS, tracer=tr,
        telemetry_path=str(out / "telemetry.jsonl"),
        manifest_path=str(out / "manifest.json"))
    texport.write_perfetto(str(out / "trace.json"), tr,
                           extra={"wall_s": summary["wall_s"]})
    texport.write_prometheus(str(out / "metrics.prom"), tr.metrics)
    errs = texport.check_trace_dir(str(out))
    assert not errs, errs

    print(f"test acc {test_acc(mean_params(state), test):.3f}, "
          f"final loss {hist[-1]['loss']:.4f}, "
          f"wall {summary['wall_s']:.2f}s "
          f"({'fenced' if args.fence else 'unfenced: dispatch time'})")
    print(f"\nspan census ({summary['trace']['spans']} spans "
          f"-> {out}/trace.json, load in ui.perfetto.dev):")
    for name, n in sorted(Counter(s.name for s in tr.spans).items()):
        tot = sum(s.dur_s or 0.0 for s in tr.spans if s.name == name)
        print(f"  {name:<12} x{n:<4} {tot * 1e3:8.1f} ms total")

    # the JOIN: ledger stage rows (bytes) x trace stage spans (seconds),
    # matched on the shared stage id
    recs = [json.loads(l) for l in open(out / "telemetry.jsonl")]
    stage_bytes: dict = {}
    for sp in tr.spans:
        if sp.name == "collective":
            stage_bytes.setdefault(sp.attrs["stage"], sp.attrs["wire_bytes"])
    stage_secs: dict = {}
    for r in recs:
        for k, v in r["stage_s"].items():
            stage_secs[int(k)] = stage_secs.get(int(k), 0.0) + v
    print("\nper-stage bytes<->seconds join "
          f"(sync_seconds={summary['ledger']['sync_seconds']:.3f}s):")
    print(f"  {'stage':>5} {'wire bytes/round':>17} {'seconds total':>14}")
    for sid in sorted(stage_secs):
        print(f"  {sid:>5} {stage_bytes.get(sid, 0):>17.0f} "
              f"{stage_secs[sid]:>14.4f}")
    print(f"\nartifacts validated under {out}/ "
          "(trace.json, metrics.prom, telemetry.jsonl, manifest.json)")


if __name__ == "__main__":
    main()
